"""Trace-driven load harness: capacity curves from the oracle fleet.

Drives the REAL scheduler/router/budget machinery (via
``runtime.workload.OraclePolicy`` — model arithmetic replaced by hash
logits, everything else byte-identical code paths) with synthetic
traffic from ``runtime.workload.generate_workload`` at 10⁵–10⁶
requests, entirely in virtual time:

- the fleet runs on a ``VirtualClock`` the driver advances per tick
  from a two-term cost model (fixed dispatch cost + per-prefill-token
  cost — decode is seat-parallel in the fused tick, prefill is the
  serial term), so TTFT/TBT and every deadline verdict are
  deterministic functions of the schedule;
- idle gaps fast-forward to the next arrival, so wall time scales
  with *work*, not with the trace's virtual span;
- structural invariants (HostBudget never over-grants bytes,
  BlockManager page partition + refcount/table agreement, no request
  lost or duplicated) are checked on a fixed tick cadence and at
  every run boundary.

Output: ``BENCH_capacity.json`` — for each offered-load multiple, the
minimum pages (at fixed replicas) and minimum replicas (at fixed
pages) that meet each SLO class's targets, plus a full soak at the
chosen operating point and a same-seed determinism self-check.  CI
gates zero invariant violations and premium TBT p95 ≤ the configured
deadline at the operating point, and renders the capacity table into
the step summary (--summary reprints it from the JSON).

Usage::

    PYTHONPATH=src python -m benchmarks.load_harness --requests 100000
    PYTHONPATH=src python -m benchmarks.load_harness --summary

See docs/benchmarks.md §"Workload 8" for how to read the curves.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from collections import Counter
from typing import Dict, List, Optional, Sequence

from repro.runtime.router import ModelFleet
from repro.runtime.telemetry import Telemetry
from repro.runtime.workload import (ArrivalEvent, VirtualClock,
                                    WorkloadSpec, add_workload_args,
                                    generate_workload, oracle_fleet,
                                    spec_from_args)

#: virtual cost of one engine tick: fixed dispatch + per-prefill-token.
#: Decode rides the fixed term (the fused tick is seat-parallel); the
#: prefill term makes long prompts and replay-after-preemption slow,
#: which is exactly the pressure that turns page shortage into TBT
#: misses and replica shortage into TTFT queueing.
TICK_BASE_S = 2e-3
TICK_PREFILL_TOKEN_S = 2e-5


class InvariantViolation(AssertionError):
    """A structural invariant of the serving stack failed mid-run."""


def check_invariants(fleet: ModelFleet) -> List[str]:
    """Return human-readable descriptions of every violated invariant
    (empty = all hold).  Checked:

    1. HostBudget never over-grants: borrowed bytes across engines fit
       the surplus, and every manager's live pages fit its pool.
    2. BlockManager partition: every physical page (minus scratch page
       0) is in exactly one of {live, free, reclaimable}.
    3. Refcount/table agreement: each pool's live refcounts equal the
       page references held by seated requests — queued or finished
       requests hold none.
    """
    errs: List[str] = []
    budget = fleet.budget
    borrowed = sum(budget.borrowed_bytes(k) for k in budget._managers)
    if borrowed > budget.surplus_bytes:
        errs.append(f"budget over-grant: borrowed {borrowed} bytes > "
                    f"surplus {budget.surplus_bytes}")
    for name, i, eng in fleet._engines():
        bm = eng.policy.bm
        if bm.in_use > bm.capacity:
            errs.append(f"{name}/{i}: {bm.in_use} live pages > "
                        f"pool capacity {bm.capacity}")
        live, free = set(bm._ref), set(bm._free)
        reclaim = set(bm._reclaim)
        total = bm.capacity
        if (len(live) + len(free) + len(reclaim) != total
                or live | free | reclaim
                != set(range(1, total + 1))):
            errs.append(
                f"{name}/{i}: page partition broken — "
                f"{len(live)} live + {len(free)} free + "
                f"{len(reclaim)} reclaimable != {total} pages "
                f"(overlaps: live∩free={len(live & free)}, "
                f"live∩reclaim={len(live & reclaim)}, "
                f"free∩reclaim={len(free & reclaim)})")
        held = Counter(pg for r in eng.seats.values() for pg in r.pages)
        if dict(held) != bm._ref:
            extra = {pg: c for pg, c in held.items()
                     if bm._ref.get(pg) != c}
            orphan = {pg: c for pg, c in bm._ref.items()
                      if held.get(pg) != c}
            errs.append(f"{name}/{i}: refcount/table mismatch — "
                        f"seats hold {extra}, pool counts {orphan}")
        for r in eng.queue:
            if r.pages:
                errs.append(f"{name}/{i}: queued rid {r.rid} holds "
                            f"{len(r.pages)} pages")
    return errs


def check_conservation(fleet: ModelFleet, submitted: Sequence[int],
                       ) -> List[str]:
    """No request lost or duplicated: the submitted rid set equals the
    disjoint union of queued, seated and finished rids fleet-wide."""
    errs: List[str] = []
    seen: Counter = Counter()
    for name, i, eng in fleet._engines():
        for r in eng.queue:
            seen[r.rid] += 1
        for r in eng.seats.values():
            seen[r.rid] += 1
        for r in eng.finished:
            seen[r.rid] += 1
    dup = {rid: c for rid, c in seen.items() if c > 1}
    if dup:
        errs.append(f"duplicated rids: {sorted(dup)[:10]}"
                    f"{'...' if len(dup) > 10 else ''}")
    lost = set(submitted) - set(seen)
    if lost:
        errs.append(f"lost rids: {sorted(lost)[:10]}"
                    f"{'...' if len(lost) > 10 else ''}")
    ghost = set(seen) - set(submitted)
    if ghost:
        errs.append(f"unsubmitted rids present: {sorted(ghost)[:10]}")
    return errs


@dataclasses.dataclass
class DriveResult:
    """One workload run through one fleet."""
    requests: int
    ticks: int
    virtual_s: float
    wall_s: float
    invariant_violations: List[str]
    snapshot: Dict[str, object]          # fleet-merged metrics snapshot
    classes: Dict[str, Dict[str, float]]
    token_digest: int                    # order-insensitive stream hash


def _token_digest(fleet: ModelFleet) -> int:
    """Order-insensitive digest of every finished request's token
    stream (rid-keyed), for same-seed determinism comparisons."""
    dig = 0
    for rid, req in fleet.finished().items():
        h = hash((rid,) + tuple(req.generated))
        dig ^= h & 0xFFFFFFFFFFFFFFFF
    return dig


def drive_workload(fleet: ModelFleet, events: Sequence[ArrivalEvent],
                   clock: VirtualClock, *,
                   tick_base_s: float = TICK_BASE_S,
                   tick_prefill_token_s: float = TICK_PREFILL_TOKEN_S,
                   invariant_interval: int = 16,
                   max_ticks: int = 5_000_000,
                   max_backlog: Optional[int] = None) -> DriveResult:
    """Replay ``events`` through ``fleet`` on ``clock`` until every
    request finishes, advancing virtual time per tick from the cost
    model (max over engines that did work this tick — engines are
    parallel devices) and fast-forwarding idle gaps to the next
    arrival.  Invariants are checked every ``invariant_interval``
    ticks (0 disables mid-run checks) and always at the end.

    ``max_backlog`` (checked on the invariant cadence) fails fast when
    the fleet-wide queue depth exceeds it — an unstable offered load
    grows the backlog without bound and each tick's admission scan is
    O(backlog), so erroring beats grinding for minutes.

    When the fleet carries a :class:`~repro.runtime.telemetry.Telemetry`
    instance, the FIRST invariant violation (and either RuntimeError)
    dumps a postmortem JSON — flight-recorder ring + every engine's
    queue/seats/BlockManager partition + HostBudget grants — before the
    run continues or raises; CI uploads it as an artifact on failure.

    Raises:
      RuntimeError: ``max_ticks`` exceeded (a scheduling stall) or
        ``max_backlog`` exceeded (an unstable offered load)."""
    t_wall = time.perf_counter()
    engines = [eng for _, _, eng in fleet._engines()]
    tel = getattr(fleet, "telemetry", None)

    def _dump(reason: str) -> None:
        if tel is not None:
            tel.write_postmortem(
                reason,
                engines={f"{n}/{i}": e for n, i, e in fleet._engines()},
                budget=fleet.budget.usage())

    violations: List[str] = []
    submitted: List[int] = []
    t0_virtual = clock.now
    i = 0
    ticks = 0
    while i < len(events) or fleet.pending():
        if (not fleet.pending() and i < len(events)
                and events[i].t > clock.now):
            clock.advance(events[i].t - clock.now)   # idle fast-forward
        while i < len(events) and events[i].t <= clock.now:
            e = events[i]
            submitted.append(fleet.submit(
                model=e.model, prompt=e.prompt,
                max_new_tokens=e.max_new_tokens, sampling=e.sampling,
                priority=e.priority, deadline_ms=e.deadline_ms,
                tbt_deadline_ms=e.tbt_deadline_ms,
                session_id=e.session_id))
            i += 1
        prefill_before = [eng.metrics.prefill_tokens for eng in engines]
        busy_before = [bool(eng.queue or eng.seats) for eng in engines]
        fleet.step()
        dt = 0.0
        for k, eng in enumerate(engines):
            if not busy_before[k]:
                continue                 # idle engines don't tick
            dt = max(dt, tick_base_s + tick_prefill_token_s
                     * (eng.metrics.prefill_tokens - prefill_before[k]))
        clock.advance(dt)
        ticks += 1
        if invariant_interval and ticks % invariant_interval == 0:
            errs = check_invariants(fleet)
            if errs and not violations:
                _dump("invariant violation (tick cadence): "
                      + "; ".join(errs[:5]))
            violations.extend(errs)
            if max_backlog is not None:
                backlog = sum(len(eng.queue) for eng in engines)
                if backlog > max_backlog:
                    msg = (f"fleet backlog {backlog} exceeds "
                           f"max_backlog={max_backlog} — the offered "
                           "load is unstable at this capacity")
                    _dump(msg)
                    raise RuntimeError(msg)
        if ticks > max_ticks:
            msg = (f"drive_workload exceeded {max_ticks} ticks with "
                   f"{len(events) - i} arrivals pending — scheduling "
                   "stall")
            _dump(msg)
            raise RuntimeError(msg)
    end_errs = (check_invariants(fleet)
                + check_conservation(fleet, submitted))
    if end_errs and not violations:
        _dump("end-of-run invariant violation: "
              + "; ".join(end_errs[:5]))
    violations.extend(end_errs)
    snap = fleet.metrics_snapshot()["fleet"]
    return DriveResult(
        requests=len(events), ticks=ticks,
        virtual_s=clock.now - t0_virtual,
        wall_s=time.perf_counter() - t_wall,
        invariant_violations=violations,
        snapshot={k: v for k, v in snap.items() if k != "classes"},
        classes=snap["classes"],         # type: ignore[arg-type]
        token_digest=_token_digest(fleet))


# ---------------------------------------------------------------------------
# Capacity sweep
# ---------------------------------------------------------------------------

def _slo_targets(args) -> Dict[str, Dict[str, float]]:
    """Per-class SLO targets in virtual seconds.  Premium answers for
    both TTFT and per-token TBT; standard for TTFT at 5× premium's
    bound; batch only for not starving (a loose TTFT roof)."""
    ttft = args.ttft_deadline_ms / 1e3
    return {
        "premium": {"ttft_p95_s": ttft,
                    "tbt_p95_s": args.tbt_deadline_ms / 1e3},
        "standard": {"ttft_p95_s": 5 * ttft},
        "batch": {"ttft_p95_s": 50 * ttft},
    }


def _meets(classes: Dict[str, Dict[str, float]], cls: str,
           targets: Dict[str, Dict[str, float]]) -> bool:
    """Whether class ``cls`` met every one of its targets in a cell
    (vacuously true when the cell saw no such traffic)."""
    got = classes.get(cls)
    if got is None or not got["completed"]:
        return True
    return all(got[metric] <= bound
               for metric, bound in targets[cls].items())


def _run_cell(spec: WorkloadSpec, seed: int, *, pages: int,
              replicas: int, args,
              telemetry: Optional[Telemetry] = None) -> DriveResult:
    """One sweep cell: fresh fleet, fresh clock, same-seed trace.
    ``telemetry`` is shared across cells (the ring is bounded), so a
    failing cell's postmortem also shows the tail of the run before."""
    clock = VirtualClock()
    fleet = oracle_fleet(
        spec, replicas=replicas, total_pages=pages,
        page_size=args.page_size, max_seats=args.max_seats,
        prefill_chunk=args.prefill_chunk, selection=args.selection,
        admission=args.admission, clock=clock, telemetry=telemetry)
    events = generate_workload(spec, seed)
    return drive_workload(
        fleet, events, clock,
        invariant_interval=args.invariant_interval,
        max_backlog=10_000)


def _cell_record(load: float, pages: int, replicas: int,
                 res: DriveResult) -> Dict[str, object]:
    keep = ("ttft_p95_s", "tbt_p95_s", "tbt_miss_rate",
            "deadline_miss_rate", "preemptions", "completed")
    return {
        "load_multiple": load, "pages": pages, "replicas": replicas,
        "requests": res.requests, "ticks": res.ticks,
        "virtual_s": round(res.virtual_s, 3),
        "wall_s": round(res.wall_s, 3),
        "invariant_violations": len(res.invariant_violations),
        "classes": {cls: {k: v for k, v in m.items() if k in keep}
                    for cls, m in res.classes.items()},
    }


def run_capacity_sweep(args,
                       telemetry: Optional[Telemetry] = None
                       ) -> Dict[str, object]:
    """The full benchmark: sweep offered load × resource ladders, find
    per-class minimum resources, soak the operating point, self-check
    determinism.  Returns the BENCH_capacity.json payload.
    ``telemetry`` (optional) attaches one flight recorder to every
    cell's fleet so failures dump a postmortem JSON."""
    loads = [float(x) for x in args.loads.split(",")]
    pages_ladder = [int(x) for x in args.pages_ladder.split(",")]
    replicas_ladder = [int(x) for x in args.replicas_ladder.split(",")]
    targets = _slo_targets(args)
    n_cells = len(loads) * (len(pages_ladder) + len(replicas_ladder))
    # the request budget: bounded sweep cells (an overloaded cell's
    # admission scan is O(queue) per tick, so cell cost grows
    # quadratically with cell size — capacity verdicts converge by
    # ~1e3 requests anyway), the rest soaks the operating point where
    # the queue, and therefore cost per request, stays bounded
    cell_requests = min(1000, max(500, args.requests // (2 * n_cells)))
    soak_requests = max(1000,
                        args.requests - n_cells * cell_requests
                        - 2 * min(2000, cell_requests))
    base = spec_from_args(args, requests=cell_requests)
    classes = ("premium", "standard", "batch")

    page_curves: Dict[str, Dict[str, Optional[int]]] = \
        {cls: {} for cls in classes}
    replica_curves: Dict[str, Dict[str, Optional[int]]] = \
        {cls: {} for cls in classes}
    cells: List[Dict[str, object]] = []
    violations = 0

    op_met: Dict[str, bool] = {}     # load -> premium met at op resources
    for load in loads:
        spec = dataclasses.replace(
            base, arrival_rate=base.arrival_rate * load)
        met_pages: Dict[str, Optional[int]] = {c: None for c in classes}
        for pages in pages_ladder:
            res = _run_cell(spec, args.workload_seed, pages=pages,
                            replicas=args.replicas, args=args,
                            telemetry=telemetry)
            violations += len(res.invariant_violations)
            cells.append(_cell_record(load, pages, args.replicas, res))
            for cls in classes:
                if met_pages[cls] is None and _meets(res.classes, cls,
                                                     targets):
                    met_pages[cls] = pages
        for cls in classes:
            page_curves[cls][str(load)] = met_pages[cls]
        met_reps: Dict[str, Optional[int]] = {c: None for c in classes}
        for replicas in replicas_ladder:
            res = _run_cell(spec, args.workload_seed, pages=args.pages,
                            replicas=replicas, args=args,
                            telemetry=telemetry)
            violations += len(res.invariant_violations)
            cells.append(_cell_record(load, args.pages, replicas, res))
            if replicas == args.replicas:
                op_met[str(load)] = _meets(res.classes, "premium",
                                           targets)
            for cls in classes:
                if met_reps[cls] is None and _meets(res.classes, cls,
                                                    targets):
                    met_reps[cls] = replicas
        for cls in classes:
            replica_curves[cls][str(load)] = met_reps[cls]

    # operating point for the soak: the largest swept load premium met
    # at exactly the operating resources (pages × replicas), CLAMPED to
    # the calibrated base rate (1.0×).  A ~1e3-request sweep cell is
    # far too short to certify queue STABILITY — an overloaded cell
    # drains its bounded backlog inside the measurement window and
    # posts healthy percentiles that a 100× longer run at the same
    # offered rate would not — so loads beyond 1.0× never host the
    # soak; the sweep cells still report their SLO verdicts per load.
    op_load = min(loads)
    for load in loads:
        if op_met.get(str(load)) and load <= 1.0:
            op_load = max(op_load, load)
    soak_spec = dataclasses.replace(
        base, requests=soak_requests,
        arrival_rate=base.arrival_rate * op_load)
    soak = _run_cell(soak_spec, args.workload_seed, pages=args.pages,
                     replicas=args.replicas, args=args,
                     telemetry=telemetry)
    violations += len(soak.invariant_violations)

    # determinism self-check: same seed, same cell, twice
    det_spec = dataclasses.replace(base, requests=min(2000,
                                                      cell_requests))
    d1 = _run_cell(det_spec, args.workload_seed, pages=args.pages,
                   replicas=args.replicas, args=args,
                   telemetry=telemetry)
    d2 = _run_cell(det_spec, args.workload_seed, pages=args.pages,
                   replicas=args.replicas, args=args,
                   telemetry=telemetry)
    deterministic = (
        d1.token_digest == d2.token_digest
        and d1.classes == d2.classes and d1.ticks == d2.ticks
        and d1.virtual_s == d2.virtual_s)
    violations += len(d1.invariant_violations)
    violations += len(d2.invariant_violations)

    prem = soak.classes.get("premium", {})
    return {
        "config": {
            "requests": args.requests, "seed": args.workload_seed,
            "pages": args.pages, "replicas": args.replicas,
            "max_seats": args.max_seats, "page_size": args.page_size,
            "selection": args.selection, "admission": args.admission,
            "loads": loads, "pages_ladder": pages_ladder,
            "replicas_ladder": replicas_ladder,
            "tick_base_s": TICK_BASE_S,
            "tick_prefill_token_s": TICK_PREFILL_TOKEN_S,
            "targets": targets,
        },
        "pages_needed": page_curves,
        "replicas_needed": replica_curves,
        "cells": cells,
        "soak": {
            "load_multiple": op_load,
            **_cell_record(op_load, args.pages, args.replicas, soak),
            "premium_tbt_p95_s": prem.get("tbt_p95_s", 0.0),
            "premium_tbt_deadline_s": args.tbt_deadline_ms / 1e3,
            # the CI gate: per-token latency at the operating point.
            # premium_slo_met additionally folds in TTFT, which a long
            # soak at an overloaded point can miss through queueing.
            "premium_tbt_met": (prem.get("tbt_p95_s", 0.0)
                                <= args.tbt_deadline_ms / 1e3),
            "premium_slo_met": _meets(soak.classes, "premium", targets),
        },
        "deterministic": deterministic,
        "invariant_violations": violations,
    }


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

def print_summary(d: Dict[str, object], out=sys.stdout) -> None:
    """Render the capacity table as GitHub markdown (CI step summary)."""
    w = out.write
    cfg = d["config"]
    w("### Serving workload 8 — capacity curves "
      "(oracle fleet, virtual time)\n\n")
    w("| offered load | premium pages | standard pages | batch pages "
      "| premium replicas | standard replicas | batch replicas |\n")
    w("|---|---|---|---|---|---|---|\n")
    for load in cfg["loads"]:
        key = str(float(load))
        row = [f"{load:g}x"]
        for curves in (d["pages_needed"], d["replicas_needed"]):
            for cls in ("premium", "standard", "batch"):
                v = curves[cls][key]
                row.append(str(v) if v is not None else ">max")
        w("| " + " | ".join(row) + " |\n")
    soak = d["soak"]
    w(f"\nsoak @ {soak['load_multiple']:g}x load, {soak['pages']} pages x "
      f"{soak['replicas']} replicas: {soak['requests']} requests, "
      f"premium TBT p95 **{soak['premium_tbt_p95_s'] * 1e3:.1f} ms** "
      f"(deadline {soak['premium_tbt_deadline_s'] * 1e3:.0f} ms, "
      f"met: **{soak['premium_tbt_met']}**; "
      f"full premium SLO incl. TTFT: {soak['premium_slo_met']}); "
      f"invariant violations: **{d['invariant_violations']}**; "
      f"deterministic same-seed: **{d['deterministic']}**\n")


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="trace-driven oracle-fleet load harness")
    p.add_argument("--requests", type=int, default=100_000,
                   help="total request budget across sweep + soak")
    p.add_argument("--pages", type=int, default=256,
                   help="operating-point host page budget")
    p.add_argument("--replicas", type=int, default=2,
                   help="operating-point replicas per model")
    p.add_argument("--max-seats", type=int, default=16)
    p.add_argument("--page-size", type=int, default=8)
    p.add_argument("--prefill-chunk", type=int, default=32)
    p.add_argument("--selection", default="slo-aware",
                   choices=["least-loaded", "round-robin", "slo-aware"])
    p.add_argument("--admission", default="slo",
                   choices=["fcfs", "slo"])
    p.add_argument("--loads", default="0.5,1.0,2.0,4.0",
                   help="offered-load multiples of the base rate")
    p.add_argument("--pages-ladder", default="96,160,256,384",
                   help="page budgets swept per load")
    p.add_argument("--replicas-ladder", default="1,2,4",
                   help="replica counts swept per load")
    p.add_argument("--invariant-interval", type=int, default=16,
                   help="check invariants every N ticks (0 = ends only)")
    p.add_argument("--flight-recorder", type=int, default=4096,
                   metavar="N",
                   help="ring capacity of the attached flight recorder "
                        "(0 disables telemetry entirely); on an "
                        "invariant violation, stall or backlog blowup "
                        "the ring + full fleet state dump to "
                        "--postmortem")
    p.add_argument("--postmortem", default="postmortem.json",
                   help="failure postmortem JSON path (CI uploads it "
                        "as an artifact when the job fails)")
    p.add_argument("--out", default="BENCH_capacity.json")
    p.add_argument("--summary", action="store_true",
                   help="print the markdown table from --out and exit")
    add_workload_args(p)
    args = p.parse_args(argv)

    if args.summary:
        with open(args.out) as f:
            print_summary(json.load(f))
        return 0

    telemetry = None
    if args.flight_recorder > 0:
        telemetry = Telemetry(ring=args.flight_recorder,
                              postmortem_path=args.postmortem)
    t0 = time.perf_counter()
    result = run_capacity_sweep(args, telemetry=telemetry)
    result["harness_wall_s"] = round(time.perf_counter() - t0, 2)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}: {len(result['cells'])} cells + soak in "
          f"{result['harness_wall_s']}s wall; invariant violations: "
          f"{result['invariant_violations']}; deterministic: "
          f"{result['deterministic']}")
    print_summary(result)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
